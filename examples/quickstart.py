"""Quickstart: LQ-SGD distributed training in ~40 lines.

Simulates an 8-device cluster on CPU (4-way data x 2-way tensor parallel),
trains a tiny Mixtral-family model with the paper's compressed gradient
all-reduce, and prints the wire savings.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax

from repro.configs import get_config
from repro.core import CompressorConfig
from repro.data.synthetic import LMDataConfig, lm_batch
from repro.launch.mesh import make_mesh, use_mesh
from repro.train.optimizer import sgd
from repro.train.step import (build_train_step, init_train_state,
                              make_model_compressor, n_dp_of)


def main():
    mesh = make_mesh((4, 2), ("data", "model"))
    cfg = get_config("mixtral-8x7b", smoke=True)   # reduced 4-expert variant

    compressor = make_model_compressor(
        cfg, CompressorConfig(name="lq_sgd", rank=1, bits=8, alpha=10.0))
    optimizer = sgd(lr=0.05)
    step_fn, _, _ = build_train_step(cfg, mesh, compressor, optimizer,
                                     remat_scan=False)

    data = LMDataConfig(vocab_size=cfg.vocab_size, seq_len=64, batch=8)
    with use_mesh(mesh):
        state = init_train_state(cfg, jax.random.PRNGKey(0), optimizer,
                                 compressor, n_dp_of(mesh))
        n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
        print(f"model: {cfg.name}  params={n_params/1e6:.2f}M  "
              f"mesh=(data=4, model=2)")
        print(f"gradient wire/step: LQ-SGD {compressor.wire_bits_per_step()/8e6:.3f}MB"
              f" vs uncompressed {n_params*4/1e6:.1f}MB "
              f"({n_params*4*8/compressor.wire_bits_per_step():.0f}x smaller)")
        jstep = jax.jit(step_fn, donate_argnums=0)
        for step in range(20):
            state, metrics = jstep(state, lm_batch(data, step))
            if step % 5 == 0 or step == 19:
                print(f"step {step:3d}  loss={float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
