"""Federated LQ-SGD on the server wire: 8 non-IID clients, straggler
drop-out, per-worker laziness, participation-weighted aggregation.

Each client samples a Dirichlet label-skewed shard of synthetic CIFAR
(small --alpha = a few classes per client), draws an independent
participation flag per round (straggler drop-out), and decides fire/skip
on its OWN gradient innovation — the server substitutes each absent or
silent worker's cached reference gradient and averages with
participation weights, as in LAQ's staleness model. The run prints the
effective uplink (skipped contributions drop their bytes), the booked
server-broadcast downlink, and each client's final staleness counter.

    PYTHONPATH=src python examples/federated.py [--steps 60] [--alpha 0.3]
        [--participation 0.5] [--clients 8]
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), ".."))

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.convergence import _accuracy, _init_cnn, _loss_fn
from repro.core import AxisComm, CompressorConfig, make_compressor
from repro.core.lazy import STALE_NS
from repro.data.synthetic import (ImageDataConfig, client_label_probs,
                                  image_batch)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--alpha", type=float, default=0.3,
                    help="Dirichlet label-skew concentration (small = "
                         "each client sees a few classes)")
    ap.add_argument("--participation", type=float, default=0.5,
                    help="per-round upload probability per client")
    ap.add_argument("--agg", default="participation",
                    choices=["participation", "sparsity"])
    ap.add_argument("--lazy-thresh", type=float, default=1.5)
    ap.add_argument("--max-stale", type=int, default=4)
    ap.add_argument("--lr", type=float, default=0.05)
    args = ap.parse_args()
    n = args.clients

    data_cfg = ImageDataConfig(batch=16, hw=16, seed=0,
                               noniid_alpha=args.alpha, n_clients=n)
    probs = client_label_probs(data_cfg.n_classes, n, args.alpha, seed=0)
    print(f"== {n} clients, Dirichlet(alpha={args.alpha}) label skew "
          f"(top-3 classes per client):")
    for c in range(n):
        top = np.argsort(probs[c])[::-1][:3]
        share = ", ".join(f"{t}:{probs[c][t]:.2f}" for t in top)
        print(f"   client {c}: {share}")

    cc = CompressorConfig(name="lq_sgd", rank=1, bits=8,
                          fuse_collectives=True,
                          lazy_thresh=args.lazy_thresh,
                          max_stale=args.max_stale,
                          topology="server",
                          participation=args.participation, agg=args.agg)
    params = _init_cnn(jax.random.PRNGKey(0))
    comp = make_compressor(cc, jax.eval_shape(lambda: params))
    bcast = lambda t: jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), t)
    state = bcast(comp.init_state(jax.random.PRNGKey(7)))
    params = bcast(params)

    def worker(params, comp_state, images, labels):
        loss, g = jax.value_and_grad(_loss_fn)(params, images, labels)
        g, comp_state, rec = comp.sync(g, comp_state, AxisComm(("data",)))
        params = jax.tree.map(lambda w, gg: w - args.lr * gg, params, g)
        return (params, comp_state, jax.lax.pmean(loss, "data"),
                jnp.asarray(rec.effective_bits(), jnp.float32),
                jnp.asarray(rec.down_bits, jnp.float32))

    vworker = jax.jit(jax.vmap(worker, axis_name="data"))
    fired = comp.wire_bits_per_step()
    print(f"\n== training: participation={args.participation}, "
          f"lazy_thresh={args.lazy_thresh}, max_stale={args.max_stale}, "
          f"agg={args.agg}")
    print(f"   full-rate uplink would be {fired / 8e3:.1f} KB/round")
    bits = []
    for step in range(args.steps):
        shards = [image_batch(data_cfg, step, client=c) for c in range(n)]
        imgs = jnp.stack([s["images"] for s in shards])
        lbls = jnp.stack([s["labels"] for s in shards])
        params, state, loss, eb, db = vworker(params, state, imgs, lbls)
        bits.append(float(eb[0]))
        if step % 10 == 0 or step == args.steps - 1:
            print(f"   step {step:3d}  loss {float(loss[0]):.4f}  "
                  f"uplink {float(eb[0]) / 8e3:6.1f} KB  "
                  f"downlink {float(db[0]) / 8e3:6.1f} KB")

    # every client applies the identical server aggregate
    for leaf in jax.tree.leaves(params):
        np.testing.assert_allclose(np.asarray(leaf[0]), np.asarray(leaf[1]),
                                   atol=1e-5)
    stale = np.asarray(state[STALE_NS]["lq_sgd"]).reshape(-1)
    print("\n== per-client staleness (rounds since last accepted upload):")
    print("   " + "  ".join(f"c{c}={int(s)}" for c, s in enumerate(stale)))

    hold = image_batch(ImageDataConfig(batch=256, hw=16, seed=0), 10_000)
    p0 = jax.tree.map(lambda x: x[0], params)
    acc = float(_accuracy(p0, hold["images"], hold["labels"]))
    ratio = np.mean(bits) / fired
    print(f"\n== result: IID held-out accuracy {acc:.3f}; mean uplink "
          f"{np.mean(bits) / 8e3:.1f} KB/round = {ratio:.2f}x the "
          f"full-rate wire")


if __name__ == "__main__":
    main()
