"""The paper's experiment, reduced: ResNet-18 on synthetic CIFAR with all
four methods (SGD / PowerSGD / TopK / LQ-SGD), reproducing the Table-I
structure: accuracy, communication size, computation time.

    PYTHONPATH=src python examples/resnet_cifar_compression.py [--steps 40]
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), ".."))

import argparse

from benchmarks.comm_cost import comm_table
from benchmarks.convergence import train_one
from repro.core import CompressorConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    args = ap.parse_args()

    methods = {
        "Original SGD": CompressorConfig(name="none"),
        "PowerSGD (Rank 1)": CompressorConfig(name="powersgd", rank=1),
        "TopK SGD": CompressorConfig(name="topk", topk_ratio=0.005),
        "LQ-SGD (Rank 1)": CompressorConfig(name="lq_sgd", rank=1, bits=8),
    }
    sizes = comm_table(rank=1, bits=8)["CIFAR-10"]
    size_of = {"Original SGD": sizes["sgd"], "PowerSGD (Rank 1)": sizes["powersgd"],
               "TopK SGD": sizes["topk"], "LQ-SGD (Rank 1)": sizes["lq_sgd"]}

    print(f"{'Method':22s} {'Accuracy':>9s} {'Size MB/epoch':>14s} {'s/step':>7s}")
    print("-" * 56)
    for name, cc in methods.items():
        acc, losses, secs = train_one(cc, steps=args.steps, full_resnet=True)
        print(f"{name:22s} {acc:9.4f} {size_of[name]:14.1f} {secs:7.3f}")
    print("\n(paper Table I at full scale: SGD .9432/3325MB, PowerSGD "
          ".9451/14MB, TopK .8821/14MB, LQ-SGD .9290/3MB)")


if __name__ == "__main__":
    main()
