"""Gradient-inversion demo (paper §V-C): reconstruct a training image from
the shared gradient, with and without LQ-SGD compression; saves the images
as .npy and prints SSIM.

    PYTHONPATH=src python examples/gia_demo.py
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), ".."))

import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.gia_ssim import _grad_fn, _init_net, _target_image
from repro.core import CompressorConfig, make_compressor
from repro.core.privacy import GIAConfig, invert_gradients, observed_gradient, ssim


def main():
    os.makedirs("experiments/gia", exist_ok=True)
    params = _init_net(jax.random.PRNGKey(0))
    img = _target_image()
    y = jnp.array([3])
    gcfg = GIAConfig(steps=300, lr=0.05, tv_coef=5e-3)

    g_raw = _grad_fn(params, img, y)
    x_sgd, _ = invert_gradients(_grad_fn, params, g_raw, img.shape, y,
                                jax.random.PRNGKey(7), gcfg)

    comp = make_compressor(CompressorConfig(name="lq_sgd", rank=1, bits=8),
                           jax.eval_shape(lambda: g_raw))
    g_lq = observed_gradient(_grad_fn, params, img, y, comp,
                             comp.init_state(jax.random.PRNGKey(1)))
    x_lq, _ = invert_gradients(_grad_fn, params, g_lq, img.shape, y,
                               jax.random.PRNGKey(7), gcfg)

    np.save("experiments/gia/original.npy", np.asarray(img))
    np.save("experiments/gia/reconstructed_sgd.npy", np.asarray(x_sgd))
    np.save("experiments/gia/reconstructed_lq_sgd.npy", np.asarray(x_lq))
    s_sgd, s_lq = float(ssim(img, x_sgd)), float(ssim(img, x_lq))
    print(f"SSIM of reconstruction — raw SGD gradient:   {s_sgd:.4f}")
    print(f"SSIM of reconstruction — LQ-SGD gradient:    {s_lq:.4f}")
    print("lower = less leakage; compression protects" if s_lq < s_sgd
          else "unexpected: compression did not reduce leakage")
    print("images saved under experiments/gia/*.npy")


if __name__ == "__main__":
    main()
