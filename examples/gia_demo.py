"""Gradient-inversion demo (paper §V-C): reconstruct a training image from
the transmitted gradient, with and without LQ-SGD compression, at BOTH a
cold-start and a steady-state attack point (compressor state threaded
through victim training); saves the images as .npy and prints SSIM/PSNR.

    PYTHONPATH=src python examples/gia_demo.py
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.gia_ssim import (_grad_fn, _init_net, _target_image,
                                 harness_config)
from repro.core import CompressorConfig
from repro.core.privacy import sweep_methods


def main():
    os.makedirs("experiments/gia", exist_ok=True)
    params = _init_net(jax.random.PRNGKey(0))
    img = _target_image()
    y = jnp.array([3])
    cfg = harness_config(quick=True)  # same schedule the CI benchmark runs
    methods = {"sgd": None,
               "lq_sgd": CompressorConfig(name="lq_sgd", rank=1, bits=8)}
    points = sweep_methods(methods, _grad_fn, params, img, y, cfg)

    np.save("experiments/gia/original.npy", np.asarray(img))
    print(f"{'method':<10} {'phase':<14} {'ssim':>8} {'psnr':>8}  threaded")
    ssims = {}
    for p in points:
        np.save(f"experiments/gia/reconstructed_{p.method}_{p.phase}.npy",
                np.asarray(p.x_hat))
        print(f"{p.method:<10} {p.phase:<14} {p.ssim:8.4f} {p.psnr:8.2f}  "
              f"{p.state_threaded}")
        ssims[(p.method, p.phase)] = p.ssim
    protected = ssims[("lq_sgd", "steady_state")] < ssims[("sgd", "steady_state")]
    print("lower = less leakage; compression protects at steady state"
          if protected else
          "unexpected: compression did not reduce steady-state leakage")
    print("images saved under experiments/gia/*.npy")


if __name__ == "__main__":
    main()
